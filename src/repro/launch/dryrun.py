import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell the *production* step function (train / prefill / decode) is
lowered with ShapeDtypeStruct inputs under the production mesh and compiled;
we record:
  * memory_analysis()  — per-device bytes (proves the cell fits 16 GB HBM),
  * cost_analysis()    — per-device HLO FLOPs / bytes accessed,
  * collective bytes   — parsed from the compiled HLO (all-gather,
    all-reduce, reduce-scatter, all-to-all, collective-permute),
  * lower/compile wall time.
Results land in a JSON file that benchmarks/roofline.py turns into the
EXPERIMENTS.md §Roofline table.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod both --out results/dryrun.json
"""

import argparse  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import get_config, get_shape, ARCH_NAMES, SHAPES  # noqa: E402
from repro.launch.mesh import HW, make_production_mesh, mesh_shape_dict  # noqa: E402
from repro.launch import hlo_cost, steps  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.models import params as pm  # noqa: E402
from repro.optim.adamw import AdamW  # noqa: E402
from repro.sharding.specs import rules_for  # noqa: E402
from repro.sharding.utils import resolve_spec, use_sharding  # noqa: E402

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt[:4].rstrip("["), _DTYPE_BYTES.get(dt, 4))
    return total


def parse_collectives(hlo_text: str) -> dict[str, int]:
    """Per-device bytes per collective kind, from post-SPMD HLO."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+(\S+)\(", ls)
        if not m:
            continue
        result_type, opname = m.groups()
        for kind in _COLLECTIVES:
            if opname == kind or opname.startswith(kind + "-start") or opname.startswith(kind + "."):
                out[kind] += _shape_bytes(result_type)
                counts[kind] += 1
                break
    out_counts = {f"n_{k}": v for k, v in counts.items()}
    out.update(out_counts)  # type: ignore[arg-type]
    return out


def _named(tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree)


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: dict | None = None):
    """Build (jitted_fn, abstract_args) for one cell.

    ``overrides`` (perf-iteration knobs):
      param_dtype / opt_dtype / compute_dtype: str
      microbatch: int           grad-accumulation chunks (train)
      ep_mode: "gather"|"psum"  MoE expert-weight strategy
      scores_dtype: "float32"|"bfloat16"  attention score blocks
      remat: "full"|"none"
    """
    import dataclasses as _dc

    from repro.kernels import attention_xla as _attn_xla

    ov = dict(overrides or {})
    cfg = get_config(arch)
    cfg_fields = {
        k: ov.pop(k)
        for k in ("param_dtype", "opt_dtype", "compute_dtype", "remat",
                  "n_heads")
        if k in ov
    }
    if cfg_fields:
        cfg = _dc.replace(cfg, **cfg_fields)
    # the knob lives on the module that reads it (the chunked kernel moved
    # to the shelf), mirroring _kref.RMSNORM_PRECISION below
    _attn_xla.CHUNKED_SCORES_DTYPE = ov.pop("scores_dtype", "float32")
    from repro.kernels import ref as _kref
    _kref.RMSNORM_PRECISION = ov.pop("norm_precision", "full")
    from repro.models import layers as _lay
    _lay.BF16_TP_REDUCE = ov.pop("bf16_tp_reduce", False)
    _lay.MEGATRON_MLP = ov.pop("megatron_mlp", False)
    from repro.models import lm as _lm
    _lm.REMAT_POLICY = ov.pop("remat_policy", "none")
    microbatch = ov.pop("microbatch", 2)
    ep_mode = ov.pop("ep_mode", "gather")
    if ov:
        raise ValueError(f"unknown overrides: {sorted(ov)}")
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    msd = mesh_shape_dict(mesh)
    rules = rules_for(cfg, shape, msd, ep_mode=ep_mode)

    metas = lm.build_metas(cfg)
    params_abs = pm.abstract_params(metas)
    pspec = pm.spec_tree(metas, rules)
    pshard = _named(pspec, mesh)

    batch_axes = rules.get("act_batch")
    bspec_tok = P(batch_axes, None)
    bspec_emb = P(batch_axes, None, None)

    def batch_shardings(b_abs):
        return {
            k: NamedSharding(mesh, bspec_emb if v.ndim == 3 else bspec_tok)
            for k, v in b_abs.items()
        }

    ctx = use_sharding(mesh, rules)

    if shape.kind == "train":
        opt = AdamW(moment_dtype=cfg.opt_dtype)
        params_abs, opt_abs = steps.abstract_state(cfg, opt)
        oshard = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            type(opt_abs)(
                mu=pspec, nu=pspec,
                step=P(),
            ),
        )
        batch_abs = steps.input_specs(cfg, shape)
        bshard = batch_shardings(batch_abs)
        # baseline microbatching: 2 grad-accumulation chunks halve the
        # per-layer residual stacks (the dominant train-memory term)
        fn = steps.make_train_step(
            cfg, opt, steps.TrainHyper(microbatch=microbatch),
            grad_shardings=pshard,
        )
        jitted = jax.jit(
            fn,
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1),
        )
        args = (params_abs, opt_abs, batch_abs)
    elif shape.kind == "prefill":
        batch_abs = steps.input_specs(cfg, shape)
        bshard = batch_shardings(batch_abs)
        cache_metas = lm.cache_metas_tree(cfg, shape.global_batch, shape.seq_len)
        cshard = _named(pm.spec_tree(cache_metas, rules), mesh)
        fn = steps.make_prefill_step(cfg, shape)
        jitted = jax.jit(
            fn,
            in_shardings=(pshard, bshard),
            out_shardings=(None, cshard),
        )
        args = (params_abs, batch_abs)
    else:  # decode
        batch_abs = steps.input_specs(cfg, shape)
        bshard = batch_shardings(batch_abs)
        cache_metas = lm.cache_metas_tree(cfg, shape.global_batch, shape.seq_len)
        cache_abs = steps.abstract_cache(cfg, shape)
        cspec = pm.spec_tree(cache_metas, rules)
        cshard = _named(cspec, mesh)
        fn = steps.make_decode_step(cfg)
        jitted = jax.jit(
            fn,
            in_shardings=(pshard, cshard, bshard),
            out_shardings=(None, cshard),
            donate_argnums=(1,),
        )
        args = (params_abs, cache_abs, batch_abs)

    return cfg, shape, mesh, ctx, jitted, args


def model_flops(cfg, shape) -> float:
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    return float(mult * n_active * tokens)


def _save_hlo(txt: str, arch: str, shape_name: str, mesh: str,
              hlo_dir: str) -> None:
    import zstandard

    d = pathlib.Path(hlo_dir)
    d.mkdir(parents=True, exist_ok=True)
    name = f"{arch}_{shape_name}_{mesh}.hlo.zst"
    (d / name).write_bytes(zstandard.compress(txt.encode()))


def load_hlo(arch: str, shape_name: str, mesh: str,
             hlo_dir: str = "results/hlo") -> str | None:
    import zstandard

    p = pathlib.Path(hlo_dir) / f"{arch}_{shape_name}_{mesh}.hlo.zst"
    if not p.exists():
        return None
    return zstandard.decompress(p.read_bytes()).decode()


def reparse(out_path: str, hlo_dir: str = "results/hlo") -> None:
    """Recompute the cost-model fields of an existing results JSON from the
    saved HLO texts (no recompilation)."""
    path = pathlib.Path(out_path)
    results = json.loads(path.read_text())
    for rec in results:
        if rec.get("status") != "ok":
            continue
        txt = load_hlo(rec["arch"], rec["shape"], rec["mesh"], hlo_dir)
        if txt is None:
            continue
        parsed = hlo_cost.analyze(txt)
        rec["hlo_flops_per_device"] = parsed["flops"]
        rec["hlo_bytes_per_device"] = parsed["hbm_bytes"]
        rec["collectives_per_device"] = {
            k: float(v) for k, v in parsed["collectives"].items()
        }
        rec["collective_bytes_per_device"] = parsed["collective_bytes"]
        print(f"reparsed {rec['arch']} x {rec['shape']} x {rec['mesh']}: "
              f"flops/dev={parsed['flops']:.3g}", flush=True)
    path.write_text(json.dumps(results, indent=1))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             hlo_dir: str | None = None, overrides: dict | None = None) -> dict:
    rec: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
    }
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if shape.name == "long_500k" and not cfg.subquadratic:
        rec["status"] = "skipped"
        rec["reason"] = "full-attention arch at 500k context (see DESIGN.md)"
        return rec
    if overrides:
        rec["overrides"] = dict(overrides)
    try:
        cfg, shape, mesh, ctx, jitted, args = build_cell(
            arch, shape_name, multi_pod, overrides
        )
        chips = mesh.devices.size
        t0 = time.perf_counter()
        with ctx:
            lowered = jitted.lower(*args)
        rec["lower_s"] = round(time.perf_counter() - t0, 2)
        t0 = time.perf_counter()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.perf_counter() - t0, 2)

        mem = compiled.memory_analysis()
        for attr in (
            "temp_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes",
        ):
            v = getattr(mem, attr, None)
            if v is not None:
                rec[attr] = int(v)
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, list):  # older jax returns [per-device dict]
            cost = cost[0] if cost else {}
        rec["xla_flops_per_device"] = float(cost.get("flops", 0.0))
        rec["xla_bytes_per_device"] = float(cost.get("bytes accessed", 0.0))
        txt = compiled.as_text()
        if hlo_dir:
            _save_hlo(txt, arch, shape_name, rec["mesh"], hlo_dir)
        # loop-aware cost model (XLA's cost_analysis counts while bodies once)
        parsed = hlo_cost.analyze(txt)
        rec["hlo_flops_per_device"] = parsed["flops"]
        rec["hlo_bytes_per_device"] = parsed["hbm_bytes"]
        rec["collectives_per_device"] = {
            k: float(v) for k, v in parsed["collectives"].items()
        }
        rec["collective_bytes_per_device"] = parsed["collective_bytes"]
        rec["chips"] = chips
        rec["model_flops"] = model_flops(cfg, shape)
        # peak HBM need per device: arguments (params+opt+cache stay resident)
        # + temporaries.  Donated args alias outputs.
        args_b = rec.get("argument_size_in_bytes", 0)
        temp_b = rec.get("temp_size_in_bytes", 0)
        out_b = rec.get("output_size_in_bytes", 0)
        alias_b = rec.get("alias_size_in_bytes", 0)
        rec["peak_bytes_per_device"] = args_b + temp_b + max(out_b - alias_b, 0)
        rec["fits_16gb"] = rec["peak_bytes_per_device"] < 16e9
    except Exception as e:  # noqa: BLE001
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument(
        "--multi-pod", choices=("single", "multi", "both"), default="both"
    )
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--append", action="store_true")
    ap.add_argument("--save-hlo", default=None,
                    help="directory to save compiled HLO text (zstd)")
    ap.add_argument("--reparse", action="store_true",
                    help="recompute costs from saved HLO, no compilation")
    args = ap.parse_args()

    if args.reparse:
        reparse(args.out, args.save_hlo or "results/hlo")
        return

    cells: list[tuple[str, str]] = []
    archs = ARCH_NAMES if (args.all or args.arch is None) else (args.arch,)
    shapes = tuple(SHAPES) if (args.all or args.shape is None) else (args.shape,)
    for a in archs:
        for s in shapes:
            cells.append((a, s))

    pods = {"single": (False,), "multi": (True,), "both": (False, True)}[
        args.multi_pod
    ]
    out_path = pathlib.Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    results: list[dict] = []
    if args.append and out_path.exists():
        results = json.loads(out_path.read_text())
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}

    for a, s in cells:
        for mp in pods:
            key = (a, s, "2x16x16" if mp else "16x16")
            if key in done:
                continue
            t0 = time.perf_counter()
            rec = run_cell(a, s, mp, hlo_dir=args.save_hlo)
            dt = time.perf_counter() - t0
            status = rec["status"]
            extra = ""
            if status == "ok":
                extra = (
                    f" peak={rec['peak_bytes_per_device']/1e9:.2f}GB"
                    f" flops/dev={rec['hlo_flops_per_device']:.3g}"
                )
            elif status == "error":
                extra = " " + rec["error"][:120]
            print(f"[{dt:7.1f}s] {a} x {s} x {rec['mesh']}: {status}{extra}",
                  flush=True)
            results.append(rec)
            out_path.write_text(json.dumps(results, indent=1))
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors -> {out_path}")


if __name__ == "__main__":
    main()
