"""Evaluation applications (paper §5.1.1).

Naive CPU-oriented ports of the *Numerical Recipes in C* routines the paper
offloads: the 2-D FFT sample application and the LU-decomposition matrix
application.  Written deliberately in loop-heavy "C translated to Python"
style — they are the *offload source*, not the optimised shelf.
"""

from repro.apps import fourier, matrix  # noqa: F401
from repro.apps.common import Stage, build_staged_variant  # noqa: F401
