"""musicgen-large [audio] — decoder-only over EnCodec tokens.
arXiv:2306.05284.  The EnCodec tokenizer frontend is stubbed (input_specs
provides the token stream); a single codebook stream is modelled — the
4-codebook delay-pattern interleave is a data-layout detail orthogonal to
this paper (see DESIGN.md)."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_head=64,
    d_ff=8192,
    vocab_size=2048,
    rope_theta=10000.0,
    frontend="audio_tokens",
)
