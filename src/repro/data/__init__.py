from repro.data.pipeline import SyntheticLMData, host_local_slice  # noqa: F401
