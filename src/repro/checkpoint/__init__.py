from repro.checkpoint.manager import CheckpointManager  # noqa: F401
from repro.checkpoint.elastic import reshard_restore  # noqa: F401
