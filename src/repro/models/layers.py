"""Shared layer primitives: norms, RoPE, SwiGLU MLP, embeddings.

Compute flows through the FunctionBlock registry (``blocks.call``) wherever a
shelf kernel exists, so the offload engine can re-bind implementations.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import blocks
from repro.models.params import ParamMeta
from repro.sharding.utils import constrain

# Tensor-parallel output projections (attention wo, MLP down, SSM out):
# False = leave the contraction to GSPMD, which all-reduces the f32 partial
# sums (structural: the partitioner places the reduction before the bf16
# rounding and no jaxpr-level cast changes that).  True = take manual
# control via shard_map: per-shard matmul with f32 MXU accumulation, round
# the partial to bf16, then psum_scatter it in bf16 directly into the
# sequence-parallel shards — one RS of bf16 instead of one AR of f32, an
# ~8x cut of the dominant TP collective (a §Perf knob).
BF16_TP_REDUCE = False


def tp_out_einsum(spec: str, a: jax.Array, b: jax.Array, cd) -> jax.Array:
    """Einsum 'bsq,qd->bsd'-shaped, contraction crossing the TP shards."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.sharding.utils import current_mesh, current_rules, resolve_spec

    mesh = current_mesh()
    if (
        not BF16_TP_REDUCE
        or mesh is None
        or "model" not in mesh.axis_names
        or a.ndim != 3
    ):
        return jnp.einsum(spec, a, b)
    rules = current_rules()
    batch_spec = resolve_spec(("act_batch",), rules)[0]
    seq_rule = rules.get("act_seq")
    scatter_seq = seq_rule == "model" and a.shape[1] % mesh.shape["model"] == 0

    in_a = P(batch_spec, None, "model")
    in_b = P("model", None)
    out = P(batch_spec, "model" if scatter_seq else None, None)

    def local(a_l, b_l):
        part = jnp.einsum(
            spec, a_l, b_l, preferred_element_type=jnp.float32
        ).astype(cd)
        if scatter_seq:
            return jax.lax.psum_scatter(
                part, "model", scatter_dimension=1, tiled=True
            )
        return jax.lax.psum(part, "model")

    return shard_map(
        local, mesh=mesh, in_specs=(in_a, in_b), out_specs=out,
        check_rep=False,
    )(a, b)


def rmsnorm(w: jax.Array, x: jax.Array, eps: float) -> jax.Array:
    return blocks.call("rmsnorm", x, w, eps=eps)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding, llama-style rotate-half.

    x: (B, S, H, d); positions: (B, S) int32.
    """
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (
        theta ** (jnp.arange(0, half, dtype=jnp.float32) / half)
    )  # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]  # (B,S,1,half)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1 = x1.astype(jnp.float32)
    xf2 = x2.astype(jnp.float32)
    out = jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# -- SwiGLU MLP ----------------------------------------------------------------


def mlp_metas(d_model: int, d_ff: int, dtype: str) -> dict:
    return {
        "gate": ParamMeta((d_model, d_ff), ("embed", "ffn"), dtype),
        "up": ParamMeta((d_model, d_ff), ("embed", "ffn"), dtype),
        "down": ParamMeta((d_ff, d_model), ("ffn", "embed"), dtype),
    }


# True = the whole SwiGLU MLP runs as one shard_map: all-gather the bf16
# sequence shards once, compute gate/up/silu/down on the local FFN shard,
# psum_scatter the bf16 output back to sequence shards.  Exactly Megatron
# TP+SP: 1 AG(bf16) + 1 RS(bf16) per MLP, and the FSDP weight gathers at
# the shard_map boundary move bf16 — versus GSPMD's 2 AG(f32) + AR(f32).
MEGATRON_MLP = False


def _megatron_mlp(p: dict, x: jax.Array, cd) -> jax.Array:
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.sharding.utils import current_mesh, current_rules, resolve_spec

    mesh = current_mesh()
    rules = current_rules()
    batch_spec = resolve_spec(("act_batch",), rules)[0]
    tp = mesh.shape["model"]
    seq_sharded = rules.get("act_seq") == "model" and x.shape[1] % tp == 0

    xs = P(batch_spec, "model" if seq_sharded else None, None)

    def local(x_l, g_l, u_l, d_l):
        if seq_sharded:
            x_full = jax.lax.all_gather(x_l, "model", axis=1, tiled=True)
        else:
            x_full = x_l
        g = jnp.einsum("bsd,df->bsf", x_full, g_l)
        u = jnp.einsum("bsd,df->bsf", x_full, u_l)
        h = jax.nn.silu(g) * u
        part = jnp.einsum(
            "bsf,fd->bsd", h, d_l, preferred_element_type=jnp.float32
        ).astype(cd)
        if seq_sharded:
            return jax.lax.psum_scatter(
                part, "model", scatter_dimension=1, tiled=True
            )
        return jax.lax.psum(part, "model")

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(xs, P(None, "model"), P(None, "model"), P("model", None)),
        out_specs=xs,
        check_rep=False,
    )(
        x.astype(cd),
        p["gate"].astype(cd),
        p["up"].astype(cd),
        p["down"].astype(cd),
    )


def mlp_forward(p: dict, x: jax.Array, compute_dtype) -> jax.Array:
    from repro.sharding.utils import current_mesh

    if MEGATRON_MLP and current_mesh() is not None and (
        "model" in current_mesh().axis_names
    ):
        return _megatron_mlp(p, x, compute_dtype)
    xc = x.astype(compute_dtype)
    g = jnp.einsum("bsd,df->bsf", xc, p["gate"].astype(compute_dtype))
    u = jnp.einsum("bsd,df->bsf", xc, p["up"].astype(compute_dtype))
    h = jax.nn.silu(g) * u
    h = constrain(h, "act_batch", None, "ffn_act")
    return tp_out_einsum("bsf,fd->bsd", h, p["down"].astype(compute_dtype),
                         compute_dtype)


# -- embeddings -----------------------------------------------------------------


def embed_metas(cfg: ArchConfig) -> dict:
    d = {
        "embedding": ParamMeta(
            (cfg.padded_vocab, cfg.d_model), ("vocab", "embed"), cfg.param_dtype,
            scale=0.02,
        )
    }
    if not cfg.tie_embeddings:
        d["lm_head"] = ParamMeta(
            (cfg.d_model, cfg.padded_vocab), ("embed", "vocab"), cfg.param_dtype,
            scale=0.02,
        )
    return d


def embed_lookup(p: dict, tokens: jax.Array, compute_dtype) -> jax.Array:
    emb = p["embedding"].astype(compute_dtype)
    return emb[tokens]


def lm_logits(p: dict, x: jax.Array, cfg: ArchConfig, compute_dtype) -> jax.Array:
    if cfg.tie_embeddings:
        w = p["embedding"].astype(compute_dtype).T
    else:
        w = p["lm_head"].astype(compute_dtype)
    logits = jnp.einsum("bsd,dv->bsv", x.astype(compute_dtype), w)
    return constrain(logits, "act_batch", None, "vocab")


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token cross entropy; logits (B,S,V), labels (B,S).

    Formulated with a one-hot contraction (not take_along_axis): a gather
    over a vocab-sharded logits tensor makes GSPMD replicate the full vocab
    dimension per device (tens of GB at 128k vocab); the one-hot form fuses
    into a sharded partial reduction instead.
    """
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    logz = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    gold = jnp.sum(shifted * onehot, axis=-1)
    return jnp.mean(logz - gold)
