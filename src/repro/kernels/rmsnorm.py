"""Fused RMSNorm kernel.

One pass over the row: mean-of-squares, rsqrt, scale — fused so the
activation is read from HBM once (XLA emits separate reduce + mul passes at
f32 widths unless it fuses; the kernel makes the fusion structural).

Block: (rows_block, d) — the whole feature dim stays in VMEM (d <= 8192 f32
= 32 KiB/row), rows_block chosen so the block is ~1 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "rows_block", "interpret"))
def rmsnorm_pallas(
    x: jax.Array,
    w: jax.Array,
    *,
    eps: float = 1e-6,
    rows_block: int = 8,
    interpret: bool = False,
) -> jax.Array:
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    rb = rows_block
    while rows % rb:
        rb //= 2
    rb = max(rb, 1)
    grid = (rows // rb,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rb, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, w.reshape(1, d))
    return out.reshape(orig_shape)
