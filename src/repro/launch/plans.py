"""Production-side loading of verified offload plans.

The planner (``repro.core.planner``) searches and persists plans in a
verification environment; the launch drivers only *load* them.  Loading is
the zero-search path: no variant is built and nothing is measured — the
stored block->target mapping is entered via ``blocks.bind`` so every jitted
step traces under the verified offload pattern.
"""

from __future__ import annotations

import contextlib

from repro.core import blocks
from repro.core.planner import PlanStore


def load_plan_bindings(
    plan_dir: str,
    key: str,
    match_fingerprint: bool = True,
    registry=None,
) -> dict[str, str] | None:
    """Fetch a stored plan's block->target mapping, or None when no plan
    (or a plan verified under a different environment) is available.

    The mapping is validated against the current block registry: a plan
    naming a block or target that no longer exists (kernel removed or
    renamed since the plan was verified) is treated as incompatible rather
    than binding something that would KeyError mid-trace.
    """
    if registry is None:
        registry = blocks.registry
    plan = PlanStore(plan_dir).load(key, match_fingerprint=match_fingerprint)
    if plan is None:
        return None
    mapping = dict(plan.mapping)
    for block, target in mapping.items():
        if target not in registry.targets(block):
            return None
    return mapping


def plan_binding_context(plan_dir: str | None, key: str | None):
    """Binding context for a stored plan; a no-op context when unset or
    when the plan is missing/incompatible (default bindings then apply)."""
    if not plan_dir or not key:
        if plan_dir or key:
            print(
                "offload plan ignored: both --plan-dir and --plan-key are "
                f"required (got plan_dir={plan_dir!r}, plan_key={key!r})"
            )
        return contextlib.nullcontext()
    mapping = load_plan_bindings(plan_dir, key)
    if mapping is None:
        print(f"plan '{key}' not found/compatible in {plan_dir}; "
              "running with default bindings")
        return contextlib.nullcontext()
    print(f"bound offload plan '{key}': {mapping} (no re-measurement)")
    return blocks.bind(mapping)
