"""Fault-tolerant training loop: checkpoint / restart / replay.

The loop owns (i) periodic async checkpoints, (ii) restart-on-failure with
restore from the latest complete checkpoint, (iii) deterministic data replay
(the pipeline is seeded per step, so re-running steps k..n after restoring
step k reproduces the original stream), and (iv) a bounded restart budget so
a persistent fault surfaces instead of looping.

``InjectedFailure`` + the ``failure_hook`` exist so tests (and chaos drills)
can kill the loop at arbitrary steps and assert bit-exact recovery.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable

from repro.checkpoint.manager import CheckpointManager
from repro.runtime.monitor import StepMonitor

log = logging.getLogger("repro.runtime")


class InjectedFailure(RuntimeError):
    """Raised by test failure hooks to simulate a node loss."""


@dataclasses.dataclass
class LoopResult:
    state: Any
    completed_steps: int
    restarts: int
    straggler_events: int


class FaultTolerantLoop:
    def __init__(
        self,
        step_fn: Callable[[Any, dict, int], Any],  # (state, batch, step) -> state
        batch_fn: Callable[[int], dict],  # step -> batch (deterministic)
        ckpt: CheckpointManager,
        ckpt_every: int = 50,
        max_restarts: int = 3,
        monitor: StepMonitor | None = None,
        failure_hook: Callable[[int], None] | None = None,
    ) -> None:
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.monitor = monitor or StepMonitor()
        self.failure_hook = failure_hook

    def run(self, state: Any, n_steps: int, start_step: int = 0) -> LoopResult:
        import jax
        import numpy as np

        restarts = 0
        step = start_step
        # host snapshot of the initial state: a restart that finds no
        # checkpoint must replay from *this*, not from the corrupted
        # in-flight state
        initial = jax.tree.map(lambda x: np.array(x, copy=True), state)
        # resume from the latest checkpoint if one exists
        if self.ckpt.latest_step() is not None:
            step, state = self.ckpt.restore(state)
            log.info("resumed from checkpoint at step %d", step)

        while step < n_steps:
            try:
                while step < n_steps:
                    if self.failure_hook is not None:
                        self.failure_hook(step)
                    batch = self.batch_fn(step)
                    self.monitor.start()
                    state = self.step_fn(state, batch, step)
                    self.monitor.stop(step)
                    step += 1
                    if step % self.ckpt_every == 0:
                        self.ckpt.save(step, state)
            except InjectedFailure as e:
                restarts += 1
                log.warning("failure at step %d: %s (restart %d)", step, e, restarts)
                if restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded restart budget ({self.max_restarts})"
                    ) from e
                if self.ckpt.latest_step() is not None:
                    step, state = self.ckpt.restore(state)
                else:
                    step = start_step
                    state = jax.tree.map(lambda x: np.array(x, copy=True),
                                         initial)
        self.ckpt.save(step, state, blocking=True)
        return LoopResult(
            state=state,
            completed_steps=step,
            restarts=restarts,
            straggler_events=len(self.monitor.events),
        )
