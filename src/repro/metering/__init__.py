"""``repro.metering`` — the measurement-and-telemetry runtime.

The planner decides *what* to measure; this package owns *how* it is
measured and what the measurement costs in energy:

  executors   ``SerialExecutor`` / ``DeviceParallelExecutor`` /
              ``BatchedExecutor`` behind the ``MeasurementExecutor``
              protocol — plugged into ``MeasurementCache(executor=...)``
              (or ``OffloadSession(..., executor=...)``) so every search
              strategy's bulk ``measure_many`` rounds run concurrently on
              multi-device hosts, or fused for sub-millisecond variants.
  meters      counter-backed ``PowerMeter``s (``NvmlMeter``, ``RaplMeter``,
              ``PsutilCpuMeter``) behind :func:`autodetect`, which degrades
              gracefully to ``TimeProportionalPower``.  Every reading is
              stamped ``measured`` vs ``estimated`` so mixed rankings stay
              auditable.
  report      ``python -m repro.metering.report`` diffs two plan stores
              into the paper's power/performance trade-off table, and
              ``search_trace`` reconstructs the Fig. 4 trials-vs-best
              curve from a report or a measurement cache.
"""

from repro.core.planner.objectives import (  # noqa: F401
    DEFAULT_DEVICE_WATTS,
    PowerMeter,
    TimeProportionalPower,
)
from repro.metering.executors import (  # noqa: F401
    BatchedExecutor,
    DeviceParallelExecutor,
    MeasureJob,
    MeasurementExecutor,
    SerialExecutor,
    resolve_executor,
)
from repro.metering.meters import (  # noqa: F401
    METER_PROBE_ORDER,
    NvmlMeter,
    PsutilCpuMeter,
    RaplMeter,
    TpuMeter,
    WindowTelemetry,
    autodetect,
    meter_window,
    resolve_meter,
)
_REPORT_NAMES = (
    "DiffRow",
    "TracePoint",
    "diff_stores",
    "render_table",
    "render_trace",
    "search_trace",
    "plan_score",
)


def __getattr__(name):
    # report is imported lazily: an eager import here would make the
    # documented `python -m repro.metering.report` CLI double-import the
    # module under runpy (RuntimeWarning + two module objects).
    if name in _REPORT_NAMES:
        from repro.metering import report

        return getattr(report, name)
    raise AttributeError(f"module 'repro.metering' has no attribute '{name}'")
