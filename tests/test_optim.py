"""Optimizer + schedule + compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import AdamW
from repro.optim.compression import _dequantize, _quantize, ef_update
from repro.optim.schedule import warmup_cosine


def test_adamw_optimizes_quadratic():
    opt = AdamW(weight_decay=0.0, grad_clip=10.0)
    params = {"w": jnp.asarray([3.0, -2.0, 5.0])}
    state = opt.init(params)
    target = jnp.asarray([1.0, 1.0, 1.0])

    @jax.jit
    def step(params, state):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        return opt.update(g, state, params, jnp.asarray(0.1))

    for _ in range(200):
        params, state = step(params, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)
    assert int(state.step) == 200


def test_adamw_bf16_moments():
    opt = AdamW(moment_dtype="bfloat16")
    params = {"w": jnp.ones(4)}
    state = opt.init(params)
    assert state.mu["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones(4) * 0.5}
    p2, s2 = opt.update(g, state, params, jnp.asarray(0.01))
    assert s2.mu["w"].dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(p2["w"])))


def test_grad_clip_bounds_update():
    opt = AdamW(grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    huge = {"w": jnp.asarray([1e6, -1e6, 1e6])}
    p2, _ = opt.update(huge, state, params, jnp.asarray(0.001))
    assert float(jnp.max(jnp.abs(p2["w"]))) < 0.1


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(s, 1e-3, 10, 100)) for s in range(100)]
    assert lrs[0] < lrs[9]  # warming up
    assert abs(lrs[10] - 1e-3) < 1e-4  # peak ~ base lr
    assert lrs[-1] < lrs[50] < lrs[11]  # decaying
    assert lrs[-1] >= 1e-4 * 0.99  # floor at min_ratio


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(1000) * 0.01, jnp.float32)
    q, s = _quantize(g)
    deq = _dequantize(q, s)
    rel = float(jnp.linalg.norm(deq - g) / jnp.linalg.norm(g))
    assert q.dtype == jnp.int8
    assert rel < 0.01


def test_error_feedback_reduces_bias():
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal(512) * 0.003, jnp.float32)
    res = jnp.zeros_like(g)
    acc_plain = jnp.zeros_like(g)
    acc_ef = jnp.zeros_like(g)
    for _ in range(50):
        q, s = _quantize(g)
        acc_plain = acc_plain + _dequantize(q, s)
        q2, s2, res = ef_update(g, res)
        acc_ef = acc_ef + _dequantize(q2, s2)
    err_plain = float(jnp.linalg.norm(acc_plain - 50 * g))
    err_ef = float(jnp.linalg.norm(acc_ef - 50 * g))
    assert err_ef <= err_plain + 1e-6
