"""Prior-work loop-offload GA (paper §3.2, refs [32][33]) — the comparison
baseline for function-block offloading.

Genome: one bit per parallelisable loop — 1 = offload (execute the loop's
accelerated/vectorised variant on the device), 0 = keep on the CPU
(interpreted).  Fitness = measured runtime of the variant in the verification
environment.  Elitist generational GA with tournament selection, single-point
crossover and per-bit mutation, plus a fitness cache so re-visited genomes
are not re-measured (the measured trial is the expensive step — on real
hardware each trial is a compile+run).

``run_ga`` records the best measured speedup of every generation, which is
exactly the curve of the paper's Fig. 4.
"""

from __future__ import annotations

import dataclasses
import random
import time
from typing import Any, Callable, Sequence

from repro.core.verify import measure

Genome = tuple[int, ...]


@dataclasses.dataclass
class GAReport:
    best_genome: Genome
    best_seconds: float
    baseline_seconds: float
    generations: list[float]  # best speedup per generation (paper Fig. 4)
    evaluations: int  # number of *measured* trials
    search_seconds: float

    @property
    def best_speedup(self) -> float:
        return self.baseline_seconds / self.best_seconds


def run_ga(
    build_variant: Callable[[Genome], Callable[..., Any]],
    n_genes: int,
    args: Sequence[Any],
    population: int = 8,
    generations: int = 8,
    mutation_rate: float = 0.1,
    elite: int = 2,
    tournament: int = 3,
    repeats: int = 2,
    seed: int = 0,
) -> GAReport:
    rng = random.Random(seed)
    t0 = time.perf_counter()

    base = measure(build_variant(tuple([0] * n_genes)), args, repeats=repeats)
    cache: dict[Genome, float] = {tuple([0] * n_genes): base.seconds}
    evaluations = 1

    def fitness(g: Genome) -> float:
        nonlocal evaluations
        if g not in cache:
            m = measure(build_variant(g), args, repeats=repeats)
            cache[g] = m.seconds
            evaluations += 1
        return cache[g]

    # initial population: random genomes (paper: random bit init over the
    # parallelisable-loop set)
    pop: list[Genome] = []
    while len(pop) < population:
        g = tuple(rng.randint(0, 1) for _ in range(n_genes))
        if g not in pop:
            pop.append(g)

    history: list[float] = []
    for _gen in range(generations):
        scored = sorted(pop, key=fitness)
        history.append(base.seconds / fitness(scored[0]))
        nxt: list[Genome] = scored[:elite]
        while len(nxt) < population:
            # tournament selection
            def pick() -> Genome:
                cand = [pop[rng.randrange(len(pop))] for _ in range(tournament)]
                return min(cand, key=fitness)

            a, b = pick(), pick()
            if n_genes > 1:
                cut = rng.randrange(1, n_genes)
                child = a[:cut] + b[cut:]
            else:
                child = a
            child = tuple(
                (1 - bit) if rng.random() < mutation_rate else bit for bit in child
            )
            nxt.append(child)
        pop = nxt

    best = min(cache, key=cache.get)  # type: ignore[arg-type]
    return GAReport(
        best_genome=best,
        best_seconds=cache[best],
        baseline_seconds=base.seconds,
        generations=history,
        evaluations=evaluations,
        search_seconds=time.perf_counter() - t0,
    )
