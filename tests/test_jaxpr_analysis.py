"""Jaxpr-level Step-1 analysis (beyond-paper: C has no compute-graph trace)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import jaxpr_analysis as ja


def test_primitive_histogram_and_dot_flops():
    def f(x, w):
        return jnp.tanh(x @ w)

    rep = ja.trace_report(
        f,
        jax.ShapeDtypeStruct((8, 16), jnp.float32),
        jax.ShapeDtypeStruct((16, 4), jnp.float32),
    )
    assert rep.histogram.get("dot_general") == 1
    assert rep.histogram.get("tanh") == 1
    assert rep.dot_flops == pytest.approx(2 * 8 * 16 * 4)


def test_scan_scales_dot_flops():
    def f(x, ws):
        def body(c, w):
            return jnp.dot(c, w), None

        y, _ = jax.lax.scan(body, x, ws)
        return y

    rep = ja.trace_report(
        f,
        jax.ShapeDtypeStruct((8, 8), jnp.float32),
        jax.ShapeDtypeStruct((5, 8, 8), jnp.float32),
    )
    assert rep.has_scan
    assert rep.dot_flops == pytest.approx(2 * 8**3 * 5)


def test_conv_flops_counted():
    def f(x, k):
        return jax.lax.conv_general_dilated(
            x, k, window_strides=(1, 1), padding="SAME"
        )

    rep = ja.trace_report(
        f,
        jax.ShapeDtypeStruct((1, 3, 8, 8), jnp.float32),  # NCHW
        jax.ShapeDtypeStruct((4, 3, 3, 3), jnp.float32),  # OIHW
    )
    # out (1,4,8,8): 256 elems; kernel 4*3*3*3 = 108 weights, 27 MACs per
    # output element (108 / 4 output features)
    assert rep.conv_flops == pytest.approx(2 * 256 * 27)
    assert rep.flops >= rep.conv_flops
    assert rep.dot_flops == 0.0


def test_fft_flops_counted():
    rep = ja.trace_report(
        lambda x: jnp.fft.fft(x),
        jax.ShapeDtypeStruct((4, 16), jnp.complex64),
    )
    # 5 N log2 N per transform, batch of 4 rows of N=16
    assert rep.fft_flops == pytest.approx(5 * 4 * 16 * 4)
    assert rep.flops >= rep.fft_flops


def test_scan_scales_conv_flops():
    def f(x, ks):
        def body(c, k):
            return jax.lax.conv_general_dilated(
                c, k, window_strides=(1, 1), padding="SAME"
            ), None

        y, _ = jax.lax.scan(body, x, ks)
        return y

    once = ja.trace_report(
        lambda x, k: jax.lax.conv_general_dilated(
            x, k, window_strides=(1, 1), padding="SAME"
        ),
        jax.ShapeDtypeStruct((1, 3, 8, 8), jnp.float32),
        jax.ShapeDtypeStruct((3, 3, 3, 3), jnp.float32),
    )
    scanned = ja.trace_report(
        f,
        jax.ShapeDtypeStruct((1, 3, 8, 8), jnp.float32),
        jax.ShapeDtypeStruct((5, 3, 3, 3, 3), jnp.float32),
    )
    assert scanned.conv_flops == pytest.approx(5 * once.conv_flops)


def test_histogram_similarity_detects_same_computation():
    """The jaxpr analogue of B-2: two differently-written FFT apps trace to
    near-identical primitive histograms; an unrelated computation does not."""

    def app_a(x):
        return jnp.abs(jnp.fft.fft2(x)) ** 2

    def app_b(y):  # renamed / re-ordered but the same block structure
        s = jnp.fft.fft2(y)
        return jnp.square(jnp.abs(s))

    def unrelated(x):
        return jnp.sort(x, axis=-1)[:, :3]

    aval = jax.ShapeDtypeStruct((16, 16), jnp.complex64)
    ha = ja.trace_report(app_a, aval).histogram
    hb = ja.trace_report(app_b, aval).histogram
    hu = ja.trace_report(unrelated, jax.ShapeDtypeStruct((16, 16), jnp.float32)).histogram
    assert ja.histogram_similarity(ha, hb) > 0.9
    assert ja.histogram_similarity(ha, hu) < 0.5


def test_model_trace_contains_expected_blocks():
    """Tracing a reduced model exposes the mixers in the histogram —
    the hook for future jaxpr-level block discovery on whole models."""
    from repro.configs import get_config
    from repro.models import lm

    cfg = get_config("llama3.2-1b").reduced()
    params = lm.init_params(cfg, 0)
    batch = {
        "tokens": jnp.zeros((1, 16), jnp.int32),
        "labels": jnp.zeros((1, 16), jnp.int32),
    }
    rep = ja.trace_report(lambda p, b: lm.loss_fn(p, b, cfg)[0], params, batch)
    assert rep.has_scan  # scan-over-layers visible at trace level
    assert rep.histogram.get("dot_general", 0) >= 4
    assert rep.dot_flops > 0
