"""pixtral-12b [vlm] — pixtral-ViT frontend (stubbed: precomputed patch
embeddings) + mistral-nemo decoder backbone. hf:mistralai/Pixtral-12B-2409."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1000000.0,
    frontend="patch_embed",
)
