"""``repro.offload`` — the public facade for automatic offloading.

One lifecycle object (``OffloadSession``: analyze -> discover -> plan ->
verify -> commit), one result type (``OffloadResult``), pluggable
objectives (``Latency``, ``PerfPerWatt``, ``WeightedCost`` over an optional
``PowerMeter``), persistent plans (``PlanStore``), and the zoo-wide
``plan_zoo`` sweep.  The historical entry points —
``OffloadEngine.adapt``, ``measure_block_pattern``, ``run_ga`` — are thin
deprecation shims over this package.

Quickstart::

    from repro.offload import OffloadSession

    result = OffloadSession(my_app, args=(x,)).run()
    y = result.fn(x)                      # accelerated application

    # production startup: bind a committed plan, zero measurement
    with OffloadSession.attach("results/plans", "zoo:llama3.2-1b:train"):
        ...
"""

from repro.core.planner import (  # noqa: F401
    DEFAULT_DEVICE_WATTS,
    Latency,
    MeasurementCache,
    Objective,
    PerfPerWatt,
    Plan,
    PlanStore,
    PowerMeter,
    TimeProportionalPower,
    WeightedCost,
    resolve_objective,
)
from repro.metering import (  # noqa: F401
    BatchedExecutor,
    DeviceParallelExecutor,
    SerialExecutor,
    autodetect,
    resolve_executor,
    resolve_meter,
)
from repro.offload.session import (  # noqa: F401
    OffloadResult,
    OffloadSession,
    StageError,
    declared_pattern,
    stored_binding,
)


def __getattr__(name):
    # zoo is imported lazily: an eager import here would make the
    # documented `python -m repro.offload.zoo` CLI double-import the
    # module under runpy (RuntimeWarning + two module objects).
    if name in ("plan_zoo", "zoo_key", "default_plan_key"):
        from repro.offload import zoo

        return getattr(zoo, name)
    raise AttributeError(f"module 'repro.offload' has no attribute '{name}'")
