"""Loop-aware HLO cost model: trip-count multiplication, dot flops."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost


def _flops_of(fn, *avals):
    txt = jax.jit(fn).lower(*avals).compile().as_text()
    return hlo_cost.analyze(txt)


def test_plain_dot_flops():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    r = _flops_of(lambda a, b: a @ b, a, b)
    assert r["flops"] == pytest.approx(2 * 64 * 128 * 32)


def test_scan_multiplies_trip_count():
    L = 8

    def f(x, ws):
        def body(x, w):
            return jnp.dot(x, w), None

        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, 64, 64), jnp.float32)
    r = _flops_of(f, x, ws)
    assert r["flops"] == pytest.approx(2 * 64**3 * L)
    # XLA's own analysis misses the loop factor — that's why this exists
    xla = jax.jit(f).lower(x, ws).compile().cost_analysis()
    if isinstance(xla, list):  # older jax returns [per-device dict]
        xla = xla[0]
    assert xla["flops"] == pytest.approx(2 * 64**3, rel=1e-3)


def test_nested_scan():
    def f(x, ws):
        def outer(x, w):
            def inner(x, _):
                return jnp.dot(x, w), None

            y, _ = jax.lax.scan(inner, x, jnp.arange(3))
            return y, None

        y, _ = jax.lax.scan(outer, x, ws)
        return y

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, 32, 32), jnp.float32)
    r = _flops_of(f, x, ws)
    assert r["flops"] == pytest.approx(2 * 32**3 * 3 * 4)


def test_shape_parser():
    elems, nbytes = hlo_cost.shape_elems_bytes("f32[16,128]{1,0}")
    assert elems == 2048 and nbytes == 8192
    elems, nbytes = hlo_cost.shape_elems_bytes("(s32[], bf16[8,8]{1,0})")
    assert nbytes == 4 + 128


def test_hbm_bytes_nonzero_and_sane():
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    r = _flops_of(lambda x: x + 1.0, a)
    # read + write of 256KB within 4x slack
    assert 0.4e6 < r["hbm_bytes"] < 3e6
